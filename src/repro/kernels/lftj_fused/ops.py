"""Public wrapper for the fused per-box LFTJ megakernel.

Takes one box's atom slices as plain compact-CSR triples ``(keys, off,
vals)`` (the kernels layer stays independent of the query layer), pads
them into the kernel's VMEM layout with power-of-two bucketed shapes —
the same jit-cache-bounding idiom as ``core/executor.py`` — and runs the
whole box join as a single device invocation:

* :func:`fused_count`  -> exact count via the Pallas megakernel
  (interpret mode off-TPU);
* :func:`fused_list`   -> (exact total, bounded deterministic-prefix
  binding buffer) via the fused XLA listing program — callers keep the
  PR-6 overflow->rescan protocol unchanged.

:func:`fused_supported` is the static gate: patterns deeper than
``MAX_DEPTH`` variables, with unordered atoms, or with an unbound
intermediate variable (a Cartesian expansion the VMEM-resident frontier
can't bound) fall back to the staged lane, as do boxes whose padded
slices exceed the VMEM budget. Every dispatch notes one device
invocation plus its padded transfer bytes on the attached
:mod:`repro.kernels.ledger`.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.kernels import ledger

from .kernel import (KEY_PAD, MAX_DEPTH, SENTINEL, VAL_SPLIT,
                     build_fused_count, build_fused_list,
                     starts_only_depths)

# padded bytes a compiled kernel may keep VMEM-resident (slices + scratch
# + working tiles); real TPU VMEM is ~16 MiB per core, leave headroom
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _pow2(n: int, lo: int = 1) -> int:
    return max(lo, 1 << int(np.ceil(np.log2(max(1, n)))))


class FusedUnsupported(ValueError):
    """Box/pattern outside the fused kernel's static envelope — callers
    fall back to the staged per-level lane."""


def fused_supported(atom_dims: Sequence[Tuple[int, int]],
                    n_vars: int) -> Optional[str]:
    """None if the pattern fits the fused kernel, else the reason."""
    if n_vars < 2:
        return "fused kernel needs at least two variables"
    if n_vars > MAX_DEPTH:
        return (f"pattern depth {n_vars} exceeds the fused kernel's "
                f"MAX_DEPTH={MAX_DEPTH} scratch bound")
    if not atom_dims:
        return "no atoms"
    seen_second = set()
    seen_first = set()
    for fd, sd in atom_dims:
        if not 0 <= fd < sd < n_vars:
            return f"atom dims ({fd}, {sd}) not forward-ordered"
        seen_second.add(sd)
        seen_first.add(fd)
    if (n_vars - 1) not in seen_second:
        return "innermost variable has no bound atom"
    for d in range(1, n_vars - 1):
        # a starts-only depth expands to a binding-independent constant
        # row (fine); a variable touching no atom at all is a free cross
        # product the VMEM-resident frontier can't bound
        if d not in seen_second and d not in seen_first:
            return (f"variable {d} touches no atom — Cartesian "
                    "expansion exceeds the VMEM frontier bound")
    return None


def _check(atom_dims, n_vars) -> None:
    reason = fused_supported(atom_dims, n_vars)
    if reason is not None:
        raise FusedUnsupported(reason)


def _key_intersection(atom_dims, atom_csrs, depth: int) -> np.ndarray:
    """Key intersection of the atoms starting at ``depth`` (host-side:
    depth 0 is the grid axis, starts-only depths ship as constants)."""
    cand: Optional[np.ndarray] = None
    for (fd, _), csr in zip(atom_dims, atom_csrs):
        if fd != depth:
            continue
        keys = np.asarray(csr[0], dtype=np.int64)
        cand = keys if cand is None else cand[np.isin(cand, keys)]
        if len(cand) == 0:
            break
    return cand if cand is not None else np.zeros(0, np.int64)


def _const_rows(atom_dims, atom_csrs, n_vars: int, interpret: bool,
                sublanes: int):
    """One SENTINEL-padded constant candidate row per starts-only depth
    (``sublanes`` > 1 replicates it into a Mosaic-friendly tile). Returns
    None when any such depth has an empty candidate set — the whole box
    result is empty and no kernel needs to launch."""
    from .kernel import starts_only_depths

    lane = 8 if interpret else 128
    rows: List[np.ndarray] = []
    widths: List[int] = []
    for d in starts_only_depths(n_vars, atom_dims):
        cand = _key_intersection(atom_dims, atom_csrs, d)
        if len(cand) == 0:
            return None, ()
        k = _pow2(len(cand), lo=lane)
        if sublanes > 1:
            row = np.full((sublanes, k), SENTINEL, np.int32)
            row[:, :len(cand)] = cand.astype(np.int32)
        else:
            row = np.full(k, SENTINEL, np.int32)
            row[:len(cand)] = cand.astype(np.int32)
        rows.append(row)
        widths.append(k)
    return rows, tuple(widths)


def _dense_rows(csr, r: int, k: int) -> np.ndarray:
    """(r, k) SENTINEL-padded dense adjacency from a compact CSR."""
    keys, off, vals = csr
    off = np.asarray(off, dtype=np.int64)
    vals = np.asarray(vals, dtype=np.int32)
    deg = np.diff(off)
    out = np.full((r, k), SENTINEL, dtype=np.int32)
    total = int(deg.sum())
    if total:
        rr = np.repeat(np.arange(len(keys)), deg)
        cc = np.arange(total, dtype=np.int64) \
            - np.repeat(np.cumsum(deg) - deg, deg)
        out[rr, cc] = vals
    return out


def _count_arrays(atom_csrs, interpret: bool):
    """Pad every atom into the count kernel's layout: keys (8, R) int32
    (KEY_PAD-padded, sublane-replicated), hi/lo (R, K) f32 halves."""
    lane = 8 if interpret else 128
    arrs: List[np.ndarray] = []
    widths: List[Tuple[int, int]] = []
    in_bytes = 0
    for csr in atom_csrs:
        keys, off, _ = csr
        deg = np.diff(np.asarray(off, dtype=np.int64))
        r = _pow2(len(keys), lo=8)
        k = _pow2(int(deg.max(initial=1)), lo=lane)
        kp = np.full((8, r), KEY_PAD, np.int32)
        kp[:, :len(keys)] = np.asarray(keys, dtype=np.int32)
        dense = _dense_rows(csr, r, k)
        hi = (dense >> VAL_SPLIT).astype(np.float32)
        lo = (dense & ((1 << VAL_SPLIT) - 1)).astype(np.float32)
        arrs += [kp, hi, lo]
        widths.append((r, k))
        in_bytes += kp.nbytes + hi.nbytes + lo.nbytes
    return arrs, tuple(widths), in_bytes


def _vmem_bytes(widths, const_widths, n_vars, atom_dims, bt: int) -> int:
    """Estimated VMEM residency of one compiled grid step."""
    from .kernel import starts_only_depths

    total = 0
    for r, k in widths:
        total += 8 * r * 4 + 2 * r * k * 4          # keys + hi/lo
    k_max = max(k for _, k in widths)
    by_second = [[] for _ in range(n_vars)]
    for ai, (_, sd) in enumerate(atom_dims):
        by_second[sd].append(ai)
    so_depths = starts_only_depths(n_vars, atom_dims)
    for d in range(1, n_vars - 1):                  # frontier scratch
        total += bt * (widths[by_second[d][0]][1] if by_second[d]
                       else const_widths[so_depths.index(d)]) * 4
    for kc in const_widths:                         # constant rows
        total += 8 * kc * 4
    total += 6 * bt * k_max * 4                     # working tiles
    return total


def fused_count(atom_dims: Sequence[Tuple[int, int]],
                atom_csrs: Sequence[Tuple[np.ndarray, np.ndarray,
                                          np.ndarray]],
                n_vars: int, *, interpret: Optional[bool] = None) -> int:
    """Exact box-join count in ONE device invocation.

    Raises :class:`FusedUnsupported` when the pattern or the padded box
    falls outside the kernel's envelope (caller falls back to the staged
    lane). An empty depth-0 frontier returns 0 without launching."""
    atom_dims = tuple(tuple(d) for d in atom_dims)
    _check(atom_dims, n_vars)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c0 = _key_intersection(atom_dims, atom_csrs, 0)
    if len(c0) == 0:
        return 0
    consts, const_widths = _const_rows(atom_dims, atom_csrs, n_vars,
                                       interpret, sublanes=8)
    if consts is None:                  # a starts-only depth is empty
        return 0
    arrs, widths, in_bytes = _count_arrays(atom_csrs, interpret)
    bt = min(8 if interpret else 128, _pow2(len(c0), lo=8))
    if not interpret and _vmem_bytes(widths, const_widths, n_vars,
                                     atom_dims, bt) > VMEM_BUDGET_BYTES:
        raise FusedUnsupported("padded box slices exceed the VMEM budget")
    t = _pow2(len(c0), lo=bt)
    c0p = np.full((t, 1), SENTINEL, np.int32)
    c0p[:len(c0), 0] = c0
    call = build_fused_count(n_vars, atom_dims, widths, const_widths,
                             bt, bool(interpret))
    out = call(c0p, *arrs, *consts)
    in_bytes += sum(c.nbytes for c in consts)
    ledger.note(1, bytes_in=in_bytes + c0p.nbytes, bytes_out=t * 4)
    return int(np.asarray(out, dtype=np.int64)[:len(c0), 0].sum())


def _list_arrays(atom_csrs):
    """Listing-program layout: keys (R,) int32 SENTINEL-padded sorted,
    adjacency (R, K) int32 SENTINEL-padded (XLA gathers directly)."""
    arrs: List[np.ndarray] = []
    in_bytes = 0
    for csr in atom_csrs:
        keys, off, _ = csr
        deg = np.diff(np.asarray(off, dtype=np.int64))
        r = _pow2(len(keys), lo=8)
        k = _pow2(int(deg.max(initial=1)), lo=8)
        kp = np.full(r, SENTINEL, np.int32)
        kp[:len(keys)] = np.asarray(keys, dtype=np.int32)
        arrs += [kp, _dense_rows(csr, r, k)]
        in_bytes += kp.nbytes + arrs[-1].nbytes
    return arrs, in_bytes


def fused_list(atom_dims: Sequence[Tuple[int, int]],
               atom_csrs: Sequence[Tuple[np.ndarray, np.ndarray,
                                         np.ndarray]],
               n_vars: int, capacity: int, *,
               interpret: Optional[bool] = None,
               ) -> Tuple[int, np.ndarray]:
    """(exact total, first ``min(total, capacity)`` bindings) in ONE
    device invocation. The returned rows are the deterministic prefix of
    the program's fixed traversal order — ``total > capacity`` signals
    overflow and the caller rescans at doubled capacity (PR-6 contract).
    """
    atom_dims = tuple(tuple(d) for d in atom_dims)
    _check(atom_dims, n_vars)
    capacity = int(capacity)
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    c0 = _key_intersection(atom_dims, atom_csrs, 0)
    if len(c0) == 0:
        return 0, np.zeros((0, n_vars), np.int64)
    consts, _ = _const_rows(atom_dims, atom_csrs, n_vars,
                            interpret=True, sublanes=1)
    if consts is None:                  # a starts-only depth is empty
        return 0, np.zeros((0, n_vars), np.int64)
    arrs, in_bytes = _list_arrays(atom_csrs)
    t = _pow2(len(c0), lo=8)
    c0p = np.full(t, SENTINEL, np.int32)
    c0p[:len(c0)] = c0
    cap = _pow2(capacity, lo=8)
    call = build_fused_list(n_vars, atom_dims, cap)
    cnt, buf = call(c0p, *arrs, *consts)
    in_bytes += sum(c.nbytes for c in consts)
    ledger.note(1, bytes_in=in_bytes + c0p.nbytes,
                bytes_out=cap * n_vars * 4 + 4)
    total = int(cnt)
    take = min(total, capacity)
    rows = np.asarray(buf, dtype=np.int64)[:take]
    return total, rows


def fused_cache_info() -> dict:
    """Compiled-program cache sizes (kernel_bench reports these next to
    the intersect kernel's shape-signature count)."""
    return {
        "count_programs": build_fused_count.cache_info().currsize,
        "list_programs": build_fused_list.cache_info().currsize,
    }
