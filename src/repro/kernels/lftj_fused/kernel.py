"""Pallas TPU megakernel: one box's *entire* frontier leapfrog on-device.

The staged device lane (``query/vectorized.py`` + ``kernels/intersect``)
round-trips host<->device once per frontier level — numpy ``searchsorted``
expands, the Pallas kernel intersects, the host filters — so a deep
pattern on a hub box is launch-bound. This kernel runs the whole loop
nest of Veldhuizen's LFTJ for one box as a single ``pallas_call``:

* every atom's box slice is staged into VMEM **once** as a lifted key row
  plus a dense SENTINEL-padded adjacency matrix (split into two f32
  halves so the MXU can gather it, see below);
* the grid walks tiles of the depth-0 frontier (the host-computed key
  intersection of the atoms starting at variable 0);
* each deeper level keeps its candidate frontier in a VMEM scratch
  buffer and iterates it with a ``fori_loop`` that rotates the buffer one
  lane per step — the same rotation idiom as ``kernels/intersect`` — with
  a fixed depth bound compiled from the query pattern;
* membership tests are full-width masked compares against the candidate
  row (a masked ``searchsorted`` without the data-dependent gather, which
  Mosaic does not vectorize);
* the innermost level reduces to a per-tile lane count; per-row counts
  leave the device as one ``(T, 1)`` int32 vector.

One-hot MXU gather
------------------
TPUs have no vectorized dynamic gather, but a row lookup is a matmul:
``onehot = (keys == v)`` then ``onehot @ adjacency``. f32 matmuls carry
24 mantissa bits while vertex ids need 31, so the adjacency matrix is
shipped as two exact f32 halves — ``hi = vals >> 15`` (<= 65536) and
``lo = vals & 0x7fff`` (< 32768) — gathered separately and recombined as
``(hi << 15) | lo``. Each one-hot row has at most one non-zero, so every
dot product is a single exact addend: the gather is bit-exact. Rows whose
key is absent (``sum(onehot) == 0``) come back SENTINEL-filled, which is
precisely the "binding dies here" encoding the pruning steps use, so
deferred key filters need no extra code. Key rows are padded with ``-1``
(never a vertex id) and the frontier with SENTINEL (never gathers).

Program shape
-------------
The kernel body is *generated* from the pattern ``atom_dims`` — the loop
nest is unrolled in Python at trace time, so each (pattern, padded-shape)
pair compiles one program; ``ops.py`` buckets shapes to powers of two to
bound that cache. Counts are int32 per frontier row (a single binding
prefix inside one box never overflows that in practice; the host-side sum
is int64).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # pltpu provides the VMEM scratch allocator; absent on old CPU wheels
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

SENTINEL = np.iinfo(np.int32).max
KEY_PAD = -1
VAL_SPLIT = 15  # adjacency value = (hi << VAL_SPLIT) | lo, both exact in f32

# the fixed depth bound of the scratch allocation: patterns with more
# variables fall back to the staged lane (ops.fused_supported)
MAX_DEPTH = 6


def _gather_rows(v, keys, hi, lo):
    """(T, K) adjacency rows of the per-frontier vertices ``v`` ((T, 1)
    int32) via the exact one-hot MXU gather; absent keys -> SENTINEL."""
    onehot = (keys == v).astype(jnp.float32)            # (T, R)
    g_hi = jnp.dot(onehot, hi, preferred_element_type=jnp.float32)
    g_lo = jnp.dot(onehot, lo, preferred_element_type=jnp.float32)
    g = (g_hi.astype(jnp.int32) << VAL_SPLIT) | g_lo.astype(jnp.int32)
    present = jnp.sum(onehot, axis=1, keepdims=True) > 0.0
    return jnp.where(present, g, SENTINEL)


def _member_mask(a, b):
    """Element-of-same-row membership ``a[i, j] in b[i, :]`` for SENTINEL-
    padded sorted rows — ``kernels/intersect``'s rotation probe, widened
    to unequal row widths by broadcasting one rotated column of ``b``
    against all of ``a`` per step."""
    kb = b.shape[1]

    def step(_, carry):
        hit, b_rot = carry
        col = b_rot[:, 0:1]
        hit = hit | ((a == col) & (col != SENTINEL))
        return hit, jnp.roll(b_rot, -1, axis=1)

    hit, _ = jax.lax.fori_loop(
        0, kb, step, (jnp.zeros(a.shape, jnp.bool_), b))
    return hit


def starts_only_depths(n_vars: int,
                       atom_dims: Sequence[Tuple[int, int]]) -> List[int]:
    """Intermediate depths whose variable only *starts* atoms: their
    candidate set is a binding-independent key intersection, shipped to
    the kernel as one constant SENTINEL-padded row per depth."""
    seen_second = {sd for _, sd in atom_dims}
    return [d for d in range(1, n_vars - 1) if d not in seen_second]


def make_fused_count_kernel(n_vars: int,
                            atom_dims: Tuple[Tuple[int, int], ...],
                            widths: Tuple[Tuple[int, int], ...],
                            const_widths: Tuple[int, ...],
                            bt: int):
    """Generate the kernel body for one (pattern, padded-shape) pair.

    Ref layout: ``(c0, keys_0, hi_0, lo_0, ..., keys_m, hi_m, lo_m,
    const_0, ..., out, scratch_1, ..., scratch_{n-2})`` — one ``(bt,
    K_d)`` int32 VMEM scratch per intermediate depth holding that level's
    rotating candidate frontier, one ``(8, Kc)`` constant candidate row
    per starts-only depth. ``widths[i] = (R_i, K_i)`` are atom ``i``'s
    padded key count and row width.
    """
    by_second: List[List[int]] = [[] for _ in range(n_vars)]
    by_first: List[List[int]] = [[] for _ in range(n_vars)]
    for ai, (fd, sd) in enumerate(atom_dims):
        by_second[sd].append(ai)
        by_first[fd].append(ai)
    n_atoms = len(atom_dims)
    so_depths = starts_only_depths(n_vars, atom_dims)

    def kernel(*refs):
        c0_ref = refs[0]
        atom_refs = refs[1:1 + 3 * n_atoms]
        const_refs = refs[1 + 3 * n_atoms:
                          1 + 3 * n_atoms + len(so_depths)]
        out_ref = refs[1 + 3 * n_atoms + len(so_depths)]
        scratch = refs[2 + 3 * n_atoms + len(so_depths):]  # depth 1..n-2

        def gathered(ai: int, v):
            # keys ship as an (8, R) sublane-replicated tile (Mosaic's
            # minimum sublane count); one row drives the one-hot compare
            k = atom_refs[3 * ai][0:1, :]
            h = atom_refs[3 * ai + 1][...]
            l = atom_refs[3 * ai + 2][...]
            return _gather_rows(v, k, h, l)

        def expand(d: int, rows: Dict[int, jnp.ndarray]):
            """Depth-d candidates: first bound atom's row, pruned by
            membership in every further bound atom's row; a starts-only
            depth broadcasts its constant candidate row."""
            atoms = by_second[d]
            if not atoms:
                c = const_refs[so_depths.index(d)][0:1, :]
                return jnp.broadcast_to(c, (bt, c.shape[1]))
            cand = rows[atoms[0]]
            for ai in atoms[1:]:
                cand = jnp.where(_member_mask(cand, rows[ai]),
                                 cand, SENTINEL)
            return cand

        def innermost(rows: Dict[int, jnp.ndarray]):
            atoms = by_second[n_vars - 1]
            base = rows[atoms[0]]
            m = jnp.where(base != SENTINEL, 1, 0)
            for ai in atoms[1:]:
                m = m * jnp.where(_member_mask(base, rows[ai]), 1, 0)
            return jnp.sum(m, axis=1, keepdims=True)     # (bt, 1) int32

        def eval_depth(d: int, rows: Dict[int, jnp.ndarray]):
            if d == n_vars - 1:
                return innermost(rows)
            buf = scratch[d - 1]
            buf[...] = expand(d, rows)
            kd = buf.shape[1]

            def body(_, acc):
                v = buf[:, 0:1]
                sub_rows = dict(rows)
                for ai in by_first[d]:
                    sub_rows[ai] = gathered(ai, v)
                acc = acc + jnp.where(v != SENTINEL,
                                      eval_depth(d + 1, sub_rows), 0)
                buf[...] = jnp.roll(buf[...], -1, axis=1)
                return acc

            return jax.lax.fori_loop(
                0, kd, body, jnp.zeros((bt, 1), jnp.int32))

        v0 = c0_ref[...]                                 # (bt, 1)
        rows0 = {ai: gathered(ai, v0) for ai in by_first[0]}
        out_ref[...] = jnp.where(v0 != SENTINEL, eval_depth(1, rows0), 0)

    return kernel


@functools.lru_cache(maxsize=64)
def build_fused_count(n_vars: int,
                      atom_dims: Tuple[Tuple[int, int], ...],
                      widths: Tuple[Tuple[int, int], ...],
                      const_widths: Tuple[int, ...],
                      bt: int, interpret: bool):
    """jit'd ``(c0 (T,1), keys_i (8,R_i), hi_i, lo_i (R_i,K_i)...,
    const_j (8,Kc_j)...) -> (T, 1) int32 per-frontier-row counts``; ``T``
    must be a multiple of the tile ``bt``. Cached per (pattern, bucketed
    shape)."""
    kernel = make_fused_count_kernel(n_vars, atom_dims, widths,
                                     const_widths, bt)
    by_second: List[List[int]] = [[] for _ in range(n_vars)]
    for ai, (_, sd) in enumerate(atom_dims):
        by_second[sd].append(ai)
    so_depths = starts_only_depths(n_vars, atom_dims)
    # depth-d scratch width = the expansion source's padded row width
    scratch_shapes = [(bt, widths[by_second[d][0]][1] if by_second[d]
                       else const_widths[so_depths.index(d)])
                      for d in range(1, n_vars - 1)]

    @jax.jit
    def call(c0, *arrs):
        t = c0.shape[0]
        in_specs = [pl.BlockSpec((bt, 1), lambda i: (i, 0))]
        for (r, k) in widths:
            in_specs += [
                pl.BlockSpec((8, r), lambda i: (0, 0)),
                pl.BlockSpec((r, k), lambda i: (0, 0)),
                pl.BlockSpec((r, k), lambda i: (0, 0)),
            ]
        for kc in const_widths:
            in_specs.append(pl.BlockSpec((8, kc), lambda i: (0, 0)))
        return pl.pallas_call(
            kernel,
            grid=(t // bt,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bt, 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((t, 1), jnp.int32),
            scratch_shapes=[pltpu.VMEM(s, jnp.int32)
                            for s in scratch_shapes],
            interpret=interpret,
        )(c0, *arrs)

    return call


# ---------------------------------------------------------------------------
# fused listing: the same loop nest as one XLA program
# ---------------------------------------------------------------------------
#
# Listing needs a data-dependent scatter (append each surviving binding at
# its running output offset) — the one primitive the Mosaic lane lacks — so
# the bounded-buffer emission runs the *same* fused loop nest as a single
# jit'd XLA program instead of a pallas_call: still one device invocation
# per box, frontier buffers device-resident, PR-6 overflow->rescan contract
# (exact total + deterministic prefix of the traversal order) preserved.

def _member_sorted(a, b):
    """Per-row membership ``a[i, j] in b[i, :]`` for sorted SENTINEL-padded
    rows — vmapped searchsorted (XLA has the real gather)."""
    pos = jax.vmap(lambda bi, ai: jnp.searchsorted(bi, ai))(b, a)
    pos = jnp.clip(pos, 0, b.shape[1] - 1)
    return (jnp.take_along_axis(b, pos, axis=1) == a) & (a != SENTINEL)


@functools.lru_cache(maxsize=64)
def build_fused_list(n_vars: int,
                     atom_dims: Tuple[Tuple[int, int], ...],
                     cap: int):
    """jit'd ``(c0 (T,), keys_i (R_i,), adj_i (R_i, K_i)...,
    const_j (Kc_j,)...) -> (total int32, buf (cap, n_vars) int32)``.

    ``keys_i`` are SENTINEL-padded sorted key vectors, ``adj_i`` the
    matching SENTINEL-padded adjacency rows, ``const_j`` the constant
    candidate row of each starts-only depth. ``total`` is the exact
    binding count; ``buf`` holds the first ``min(total, cap)`` bindings of
    the fixed traversal order (emission offsets are a running cumsum, so
    the buffer is a true deterministic prefix — rescans extend, never
    reorder)."""
    by_second: List[List[int]] = [[] for _ in range(n_vars)]
    by_first: List[List[int]] = [[] for _ in range(n_vars)]
    for ai, (fd, sd) in enumerate(atom_dims):
        by_second[sd].append(ai)
        by_first[fd].append(ai)
    so_depths = starts_only_depths(n_vars, atom_dims)
    n_atom_arrs = 2 * len(atom_dims)

    @jax.jit
    def call(c0, *arrs):
        def row_of(ai, v):
            keys, adj = arrs[2 * ai], arrs[2 * ai + 1]
            pos = jnp.clip(jnp.searchsorted(keys, v), 0, keys.shape[0] - 1)
            ok = (keys[pos] == v) & (v != SENTINEL)
            return jnp.where(ok[:, None], adj[pos], SENTINEL)

        def expand(d, rows, t):
            atoms = by_second[d]
            if not atoms:
                c = arrs[n_atom_arrs + so_depths.index(d)]
                return jnp.broadcast_to(c[None, :], (t, c.shape[0]))
            cand = rows[atoms[0]]
            for ai in atoms[1:]:
                cand = jnp.where(_member_sorted(cand, rows[ai]),
                                 cand, SENTINEL)
            return cand

        def rec(d, vals, rows, carry):
            t = vals[0].shape[0]
            if d == n_vars - 1:
                f = expand(d, rows, t)                    # (T, K)
                buf, cnt = carry
                t, kk = f.shape
                # a binding that died at an earlier depth (SENTINEL in
                # vals) may still see live innermost rows when those rows
                # don't depend on the dead variable (e.g. a starts-only
                # depth) — gate the emission on the whole binding prefix
                live = jnp.ones((t,), jnp.bool_)
                for v in vals:
                    live = live & (v != SENTINEL)
                mask = ((f != SENTINEL) & live[:, None]).reshape(-1)
                flat = jnp.stack(
                    [jnp.broadcast_to(v[:, None], (t, kk)).reshape(-1)
                     for v in vals] + [f.reshape(-1)], axis=1)
                idx = cnt + jnp.cumsum(mask.astype(jnp.int32)) - 1
                buf = buf.at[jnp.where(mask, idx, cap)].set(
                    flat, mode="drop")
                return buf, cnt + jnp.sum(mask, dtype=jnp.int32)
            cand = expand(d, rows, t)

            def body(_, st):
                cand_rot, buf, cnt = st
                v = cand_rot[:, 0]
                sub_rows = dict(rows)
                for ai in by_first[d]:
                    sub_rows[ai] = row_of(ai, v)
                buf, cnt = rec(d + 1, vals + [v], sub_rows, (buf, cnt))
                return jnp.roll(cand_rot, -1, axis=1), buf, cnt

            _, buf, cnt = jax.lax.fori_loop(
                0, cand.shape[1], body, (cand, *carry))
            return buf, cnt

        buf0 = jnp.full((cap, n_vars), SENTINEL, jnp.int32)
        rows0 = {ai: row_of(ai, c0) for ai in by_first[0]}
        buf, cnt = rec(1, [c0], rows0, (buf0, jnp.int32(0)))
        return cnt, buf

    return call
