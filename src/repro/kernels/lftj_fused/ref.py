"""Scalar numpy reference for the fused per-box frontier leapfrog.

Same program the Pallas megakernel (``kernel.py``) executes, written as a
plain depth-first recursion over one box's atom slices — the oracle the
hypothesis suite pins the device lane against. An atom is a box-restricted
binary relation in compact CSR form ``(keys, off, vals)`` with ``keys``
the sorted first-variable vertex ids and ``vals`` the concatenated sorted
adjacency; ``atom_dims[i] = (first_dim, second_dim)`` places atom ``i`` in
the variable order (``first_dim < second_dim``, the orientation the
QueryEngine's planner guarantees).

Semantics per depth ``d >= 1``: candidates are the adjacency row of the
first atom bound at ``d`` (first atom with ``second_dim == d``), pruned by
row membership in every further bound atom. Depth 0 candidates are the
key-set intersection of the atoms *starting* at 0. Key filters for
``first_dim >= 1`` atoms are applied implicitly — a binding whose row is
absent from a later atom's key set gathers an empty row there and dies at
that atom's ``second_dim`` — which is exactly how the device kernel's
SENTINEL-filled gather handles them.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

SENTINEL = np.iinfo(np.int32).max


def _row(csr, v: int) -> np.ndarray:
    keys, off, vals = csr
    i = int(np.searchsorted(keys, v))
    if i >= len(keys) or keys[i] != v:
        return np.zeros(0, np.int64)
    return np.asarray(vals[off[i]:off[i + 1]], dtype=np.int64)


def fused_ref(atom_dims: Sequence[Tuple[int, int]],
              atom_csrs: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]],
              n_vars: int, mode: str = "count",
              ) -> Tuple[int, Optional[np.ndarray]]:
    """(exact count, bindings or None) of the box join.

    ``mode == "list"`` materializes every binding as a row of an
    ``(count, n_vars)`` int64 matrix, in depth-first binding order."""
    by_second: List[List[int]] = [[] for _ in range(n_vars)]
    by_first: List[List[int]] = [[] for _ in range(n_vars)]
    for ai, (fd, sd) in enumerate(atom_dims):
        if not 0 <= fd < sd < n_vars:
            raise ValueError(f"atom {ai}: bad dims ({fd}, {sd})")
        by_second[sd].append(ai)
        by_first[fd].append(ai)

    def key_intersection(d: int) -> np.ndarray:
        cand: Optional[np.ndarray] = None
        for ai in by_first[d]:
            keys = np.asarray(atom_csrs[ai][0], dtype=np.int64)
            cand = keys if cand is None else cand[np.isin(cand, keys)]
        return cand if cand is not None else np.zeros(0, np.int64)

    cand0 = key_intersection(0)
    count = 0
    rows: List[List[int]] = []

    def expand(d: int, binding: List[int]) -> np.ndarray:
        if not by_second[d]:
            # starts-only depth: binding-independent constant candidates
            return key_intersection(d)
        cand: Optional[np.ndarray] = None
        for ai in by_second[d]:
            r = _row(atom_csrs[ai], binding[atom_dims[ai][0]])
            cand = r if cand is None else cand[np.isin(cand, r)]
            if len(cand) == 0:
                break
        return cand if cand is not None else np.zeros(0, np.int64)

    def rec(d: int, binding: List[int]) -> None:
        nonlocal count
        cand = expand(d, binding)
        if d == n_vars - 1:
            count += len(cand)
            if mode == "list":
                for v in cand:
                    rows.append(binding + [int(v)])
            return
        for v in cand:
            rec(d + 1, binding + [int(v)])

    for v in cand0:
        rec(1, [int(v)])

    if mode != "list":
        return count, None
    out = (np.asarray(rows, dtype=np.int64).reshape(count, n_vars)
           if count else np.zeros((0, n_vars), np.int64))
    return count, out
