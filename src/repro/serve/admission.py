"""Admission control: N concurrent queries partition one memory budget.

The paper's I/O envelopes (Thm. 10 for triangles, Thm. 13 rank-r for
general patterns) are statements about a *single* execution with memory
``M``: the box planner cuts the variable space so every box's working set
fits ``M``, and the measured block reads stay within ``O(|E|^{3/2}/(MB))``
(resp. ``O(|I|^r/(M^{r-1}B))``). A resident service breaks that silently
if every concurrent query assumes the whole budget — N queries each
planned against ``M`` jointly hold ``N·M`` words and the per-query
envelope means nothing.

``AdmissionController`` restores the invariant by *partitioning*: a query
is admitted with a reservation ``m_i`` carved out of the global
``total_words``, plans its boxes against ``m_i`` (never the global
budget), and holds the reservation until it finishes. The controller
guarantees

    Σ_i m_i  ≤  total_words          (never oversubscribed)
    m_i      ≥  min_words            (a grant you can actually plan with)

Grant sizing is *fair-share*: an arrival under contention is offered
``total // (active + waiting + 1)`` (floored at ``min_words``, rounded
down to a power of two so the per-budget plan/compile caches converge on
a handful of distinct budgets instead of one per admission). Reclaiming
is release-driven: a finishing query's words return to the pool and every
waiter is re-notified — the fair share grows back as load drains.

When admission would oversubscribe, callers either *queue* (bounded by
``queue_depth``; a full queue rejects immediately) or time out:
``AdmissionRejected`` / ``AdmissionTimeout`` are the graceful-degradation
surface the server maps to per-query errors.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Optional


class AdmissionError(RuntimeError):
    """Base class of admission failures (never raised itself)."""


class AdmissionRejected(AdmissionError):
    """No capacity and no queue slot: the submission is turned away."""


class AdmissionTimeout(AdmissionError):
    """Queued for admission but capacity did not free up in time."""


def _pow2_floor(n: int) -> int:
    return 1 << (max(1, int(n)).bit_length() - 1)


@dataclass
class Reservation:
    """One admitted query's slice of the budget. Release exactly once
    (idempotent); ``words`` is the planning budget ``m_i``."""

    words: int
    tag: object = None
    _ctrl: Optional["AdmissionController"] = field(default=None, repr=False)
    _released: bool = field(default=False, repr=False)

    def release(self) -> None:
        if self._released or self._ctrl is None:
            return
        self._released = True
        self._ctrl._release(self)

    def __enter__(self) -> "Reservation":
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False


class AdmissionController:
    """Partitions ``total_words`` into per-query reservations."""

    def __init__(self, total_words: int, *,
                 min_words: int = 1 << 12,
                 max_active: Optional[int] = None,
                 queue_depth: int = 8):
        self.total_words = int(total_words)
        self.min_words = max(1, int(min_words))
        if self.min_words > self.total_words:
            raise ValueError(
                f"min_words {self.min_words} exceeds the total budget "
                f"{self.total_words}: nothing could ever be admitted")
        self.max_active = max_active if max_active is None \
            else max(1, int(max_active))
        self.queue_depth = max(0, int(queue_depth))
        self._cond = threading.Condition()
        self._reserved = 0
        self._active = 0
        self._waiting = 0
        # telemetry for the load benchmark / stress suite
        self.peak_active = 0
        self.peak_reserved = 0
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_timeouts = 0
        self.n_queued = 0

    # -- introspection (the stress suite's invariants) -----------------------

    @property
    def reserved_words(self) -> int:
        with self._cond:
            return self._reserved

    @property
    def active(self) -> int:
        with self._cond:
            return self._active

    @property
    def waiting(self) -> int:
        with self._cond:
            return self._waiting

    # -- admission -----------------------------------------------------------

    def _grant_locked(self, want: Optional[int]) -> Optional[int]:
        """Grant size if admissible right now, else ``None``. The offer is
        the fair share under current contention, power-of-two floored,
        clipped to the remaining pool; ``want`` caps it from above."""
        if self.max_active is not None and self._active >= self.max_active:
            return None
        avail = self.total_words - self._reserved
        if avail < self.min_words:
            return None
        share = self.total_words // (self._active + self._waiting + 1)
        grant = max(self.min_words, _pow2_floor(share))
        if want is not None:
            grant = min(grant, max(self.min_words, int(want)))
        grant = min(grant, avail)
        if grant >= self.min_words and grant > _pow2_floor(grant):
            # keep the pow2 quantization whenever it doesn't starve the
            # grant below min_words (distinct budgets stay logarithmic)
            q = _pow2_floor(grant)
            if q >= self.min_words:
                grant = q
        return grant

    def acquire(self, want_words: Optional[int] = None, *,
                timeout: Optional[float] = None,
                block: bool = True,
                tag: object = None) -> Reservation:
        """Admit one query: returns its ``Reservation`` (budget ``m_i``).

        ``want_words`` caps the grant (e.g. a known-small query declining
        the full fair share). ``block=False`` turns a would-queue into an
        immediate ``AdmissionRejected``; otherwise the caller queues —
        bounded by ``queue_depth`` — until capacity frees or ``timeout``
        (seconds) elapses (``AdmissionTimeout``)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            grant = self._grant_locked(want_words)
            if grant is None:
                if not block:
                    self.n_rejected += 1
                    raise AdmissionRejected(
                        f"admission would oversubscribe: {self._reserved}"
                        f"/{self.total_words} words reserved, "
                        f"{self._active} active")
                if self._waiting >= self.queue_depth:
                    self.n_rejected += 1
                    raise AdmissionRejected(
                        f"admission queue full ({self._waiting} waiting, "
                        f"depth {self.queue_depth})")
                self._waiting += 1
                self.n_queued += 1
                try:
                    while grant is None:
                        remaining = None if deadline is None \
                            else deadline - time.monotonic()
                        if remaining is not None and remaining <= 0:
                            self.n_timeouts += 1
                            raise AdmissionTimeout(
                                f"no capacity within {timeout}s "
                                f"({self._reserved}/{self.total_words} "
                                "words reserved)")
                        self._cond.wait(remaining)
                        grant = self._grant_locked(want_words)
                finally:
                    self._waiting -= 1
            self._reserved += grant
            self._active += 1
            self.n_admitted += 1
            self.peak_active = max(self.peak_active, self._active)
            self.peak_reserved = max(self.peak_reserved, self._reserved)
            assert self._reserved <= self.total_words, \
                "admission invariant violated: Σ reservations > total"
            return Reservation(words=grant, tag=tag, _ctrl=self)

    def _release(self, res: Reservation) -> None:
        with self._cond:
            self._reserved -= res.words
            self._active -= 1
            assert self._reserved >= 0 and self._active >= 0
            self._cond.notify_all()
