"""Persistent in-process query serving: ``Server`` + ``Session``.

The one-shot engines (``TriangleEngine``, ``QueryEngine``) pay their warm-up
on every call: open the store, plan the boxes, cold caches. A resident
``Server`` keeps everything warm and serves *concurrent* queries against one
memory budget without giving up the paper's per-query I/O envelopes:

* **warm stores** — every relation is opened once (mmap ``EdgeStore`` /
  in-memory CSR), registered on ONE shared ``BlockDevice``;
* **admission control** (``serve.admission``) — a query is admitted with a
  reservation ``m_i`` partitioning ``mem_words``; its boxes are planned
  against ``m_i`` (never the global budget), so Thm. 10/13 hold per query;
  oversubscription queues (bounded) or rejects gracefully;
* **per-query device partitions** — the shared device's frames are split
  with ``BlockDevice.open_tag``: each query's reads run under
  ``device.attributed(qid)`` against a private ``m_i/B``-frame LRU, so one
  query's scan can't thrash another's frames and the global ledger is the
  exact sum over queries;
* **shared slice cache** (``serve.cache``) — per relation, ONE
  ``SharedSliceCache`` spanning queries: floor-protected eviction keeps
  each tenant's guaranteed slice resident while overlapping queries feed
  each other hits;
* **plan cache** — box plans are memoized per (pattern shape, order,
  budget, skew) the same way ``core.engine`` keys its crossover cache, so
  a repeated pattern shape skips planning entirely (and keeps hitting the
  same jit-compiled kernel shapes);
* **retry rounds** (``runtime.straggler.BoxScheduler``) — boxes are
  idempotent, so a failed stage (I/O error, fault injection) is captured
  per box, the completed boxes keep their results, and only the failed
  ones re-queue — with completion dedup by box id — for up to
  ``box_retries`` extra rounds;
* **streamed listing** — ``submit(..., stream=True)`` pages bindings out
  in plan order through a bounded queue (the PR-6 bounded-buffer protocol
  one level up: per-box buffers bound memory inside a box, the page queue
  bounds it across boxes; a full queue backpressures the worker pool).

Everything runs in-process on threads (the PR-4 worker pool underneath);
``Session`` is the blocking convenience facade.
"""

from __future__ import annotations

import itertools
import os
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import (BoxQueueCancelled, merge_queue_telemetry,
                                 run_box_queue)
from repro.core.iomodel import BlockDevice
from repro.core.lftj_jax import csr_from_edges, orient_edges
from repro.core.queries import Query, validate
from repro.data.edgestore import EdgeStore, InMemoryEdgeSource
from repro.parallel.fabric import Fabric, FabricStats
from repro.query.executor import QueryEngine, QueryStats
from repro.query.patterns import PATTERNS
from repro.runtime.straggler import BoxScheduler

from .admission import AdmissionController, AdmissionError
from .cache import SharedSliceCache, TenantView


class QueryError(RuntimeError):
    """Base class of per-query serving failures."""


class QueryCancelled(QueryError):
    """The query's ``cancel()`` fired before its boxes drained."""


class QueryFailed(QueryError):
    """The query exhausted its retry rounds; ``cause`` is the last box
    error. The failure is contained: the server keeps serving, and the
    shared caches hold only blocks written through normal reads."""

    def __init__(self, msg: str, cause: Optional[BaseException] = None):
        super().__init__(msg)
        self.cause = cause


class _BoxError:
    """Captured per-box stage exception: a marker *result* instead of a
    raised error, so one bad box never cancels the whole queue — the
    round loop re-queues exactly the marked boxes."""

    __slots__ = ("exc",)

    def __init__(self, exc: BaseException):
        self.exc = exc


_END = object()                      # page-stream terminator


class _PageStream:
    """Plan-order reorder buffer feeding a bounded page queue.

    Boxes complete out of order across the worker pool; listing pages must
    stream in plan order (the determinism contract). ``offer(idx, rows)``
    parks results until the next-expected plan index arrives, then emits
    its pages — split to ``page_rows`` — into a bounded ``queue.Queue``.
    A full queue *blocks the offering worker* (backpressure on the pool);
    the block is cancellable, so an abandoned consumer can't wedge the
    server."""

    def __init__(self, head_fn, cancel: threading.Event,
                 page_rows: int, depth: int):
        self._head = head_fn
        self._cancel = cancel
        self._page_rows = max(1, int(page_rows))
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(depth)))
        self._lock = threading.Lock()
        self._pending: Dict[int, Optional[np.ndarray]] = {}
        self._offered: set = set()
        self._next = 0
        self.n_pages = 0

    def offer(self, idx: int, rows: Optional[np.ndarray]) -> None:
        ready: List[np.ndarray] = []
        with self._lock:
            if idx in self._offered:     # a straggler duplicate / retry
                return
            self._offered.add(idx)
            self._pending[idx] = rows
            while self._next in self._pending:
                r = self._pending.pop(self._next)
                self._next += 1
                if r is not None and len(r):
                    proj = self._head(r)
                    for s in range(0, len(proj), self._page_rows):
                        ready.append(proj[s:s + self._page_rows])
        for page in ready:
            self._put(page)

    def _put(self, item) -> None:
        while True:
            if self._cancel.is_set():
                return               # consumer abandoned: drop, don't wedge
            try:
                self._q.put(item, timeout=0.05)
                self.n_pages += item is not _END and not \
                    isinstance(item, BaseException)
                return
            except queue.Full:
                continue

    def finish(self, error: Optional[BaseException] = None) -> None:
        self._put(error if error is not None else _END)

    def __iter__(self):
        while True:
            try:
                item = self._q.get(timeout=0.1)
            except queue.Empty:
                if self._cancel.is_set():
                    raise QueryCancelled("query cancelled") from None
                continue
            if item is _END:
                return
            if isinstance(item, BaseException):
                raise item
            yield item


class QueryHandle:
    """One submitted query: status, result, pages, cancel, stats."""

    def __init__(self, qid: str, query: Query, mode: str):
        self.qid = qid
        self.query = query
        self.mode = mode
        self.status = "queued"       # -> running -> done|error|cancelled
        self.admitted_words: int = 0
        self.cache_floor: int = 0
        self.stats: Optional[QueryStats] = None
        self.retry_rounds: int = 0
        self._cancel = threading.Event()
        self._done = threading.Event()
        self.submitted_at: Optional[float] = None  # perf_counter at submit
        self._result = None
        self._error: Optional[BaseException] = None
        self._stream: Optional[_PageStream] = None
        self._thread: Optional[threading.Thread] = None

    def cancel(self) -> None:
        """Cooperative cancel: no further box is claimed, in-progress boxes
        finish (they're idempotent — resubmitting re-runs them exactly),
        admission and cache registrations release."""
        self._cancel.set()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        """Block for the final result: the count (mode='count') or the
        (m, len(head)) binding rows (mode='list'). Raises
        ``QueryCancelled`` / ``QueryFailed`` / ``TimeoutError``."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"query {self.qid} still "
                               f"{self.status} after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def pages(self):
        """Iterate listing pages in plan order (``stream=True`` handles
        only): each page is an (≤page_rows, len(head)) array. Raises the
        query's failure/cancellation mid-iteration."""
        if self._stream is None:
            raise QueryError(
                f"query {self.qid} was not submitted with stream=True; "
                "use result()")
        return iter(self._stream)


class Server:
    """Resident concurrent query service over warm relations (module doc).

    Parameters
    ----------
    relations : mapping name -> relation source: an ``EdgeStore`` or a
        path to one (mmap, warm), an ``InMemoryEdgeSource``, or a directed
        ``(src, dst)`` edge-array pair.
    mem_words : the TOTAL working-memory budget concurrent queries
        partition (admission grants ``m_i`` slices of it).
    cache_words : shared per-relation ``SharedSliceCache`` budget;
        default ``mem_words`` (the resident slice memory mirrors the
        working budget). 0 disables the shared cache.
    max_active / queue_depth / min_words : admission knobs
        (``serve.admission.AdmissionController``).
    workers_per_query : box-pool threads each query runs on.
    box_retries : extra rounds re-queuing failed boxes before the query
        errors out.
    page_rows / page_queue_depth : streamed-listing pagination.
    backend / skew / heavy_threshold / use_pallas_kernels : forwarded to
        every ``QueryEngine``.
    """

    def __init__(self, relations: Dict[str, object], *,
                 mem_words: int,
                 cache_words: Optional[int] = None,
                 io_block_words: int = 4096,
                 min_words: int = 1 << 12,
                 max_active: int = 8,
                 queue_depth: int = 8,
                 workers_per_query: int = 1,
                 box_retries: int = 2,
                 page_rows: int = 4096,
                 page_queue_depth: int = 4,
                 backend: str = "auto",
                 skew: str = "uniform",
                 heavy_threshold: Optional[int] = None,
                 use_pallas_kernels: Optional[bool] = None,
                 tracer=None,
                 metrics=None):
        if not relations:
            raise ValueError("Server needs at least one relation")
        # observability: one obs.trace.Tracer spanning every query this
        # server runs (admission -> plan -> boxes -> pages), and one
        # MetricsRegistry adopting the server's ledgers (device tags,
        # shared caches, per-query latency histograms)
        self.tracer = tracer
        self.metrics = metrics
        self.mem_words = int(mem_words)
        self.cache_words = self.mem_words if cache_words is None \
            else int(cache_words)
        self.workers_per_query = max(1, int(workers_per_query))
        self.box_retries = max(0, int(box_retries))
        self.page_rows = int(page_rows)
        self.page_queue_depth = int(page_queue_depth)
        self.backend = backend
        self.skew = skew
        self.heavy_threshold = heavy_threshold
        if use_pallas_kernels is None:
            import jax
            use_pallas_kernels = jax.default_backend() == "tpu"
        self._use_pallas = bool(use_pallas_kernels)

        self.device = BlockDevice(
            block_words=io_block_words,
            cache_blocks=max(2, self.mem_words // io_block_words))
        self.admission = AdmissionController(
            self.mem_words, min_words=min_words,
            max_active=max_active, queue_depth=queue_depth)
        # per-tenant guaranteed cache slice: the shared budget split by the
        # admission concurrency bound (Σ floors ≤ budget by construction)
        self.floor_words = self.cache_words // max(1, max_active) \
            if self.cache_words > 0 else 0

        # -- warm the relations -------------------------------------------
        self._sources: Dict[str, object] = {}
        self._specs: Dict[str, tuple] = {}   # how solo_run rebuilds them
        for name, spec in relations.items():
            self._sources[name] = self._open_source(name, spec, self.device)
        self.caches: Dict[str, SharedSliceCache] = {}
        if self.cache_words > 0:
            for name, src in self._sources.items():
                self.caches[name] = SharedSliceCache(src, self.cache_words,
                                                     tracer=tracer)
        if metrics is not None:
            metrics.adopt_device(self.device)
            for name, cache in self.caches.items():
                metrics.adopt_shared_cache(cache, relation=name)

        self._plans: Dict[str, object] = {}
        self._orders: Dict[tuple, Tuple[str, ...]] = {}
        self.plan_hits = 0
        self.plan_misses = 0
        self._lock = threading.Lock()
        self._qid = itertools.count()
        self._handles: Dict[str, QueryHandle] = {}
        self._closed = False
        # test hook: callable(stage, qid, plan_idx) run at the head of a
        # box stage; raising injects a fault into exactly that box attempt
        self.fault_hook = None

    # -- relation warm-up -----------------------------------------------------

    def _open_source(self, name: str, spec, device: BlockDevice):
        if isinstance(spec, (str, os.PathLike)):
            src = EdgeStore(spec, device=device)
            self._specs[name] = ("store", src.path)
            return src
        if hasattr(spec, "read_rows"):
            src = spec
            if isinstance(src, EdgeStore):
                src.attach_device(device)
                self._specs[name] = ("store", src.path)
            else:
                if getattr(src, "device", None) is None:
                    src.device = device
                    if src.n_edges:
                        device.register(src.indices)
                self._specs[name] = ("memory", src.indptr, src.indices,
                                     getattr(src, "orientation", "raw"))
            return src
        if isinstance(spec, tuple) and len(spec) == 2 \
                and not isinstance(spec[0], str):
            u = np.asarray(spec[0], dtype=np.int64)
            v = np.asarray(spec[1], dtype=np.int64)
            nv = int(max(u.max(initial=-1), v.max(initial=-1))) + 1
            if len(u):
                e = np.unique(np.stack([u, v], axis=1), axis=0)
                u, v = e[:, 0], e[:, 1]
            ip, ix = csr_from_edges(u, v, n_nodes=nv) if nv else \
                (np.zeros(1, np.int64), np.zeros(0, np.int32))
            src = InMemoryEdgeSource(ip, ix, orientation="raw",
                                     device=device)
            self._specs[name] = ("memory", ip, ix, "raw")
            return src
        raise ValueError(f"relation {name!r}: unsupported source "
                         f"{type(spec)}")

    @classmethod
    def from_graph(cls, src, dst, *, relation: str = "E",
                   orientation: str = "minmax", **kw) -> "Server":
        """Server over one undirected graph, oriented exactly as
        ``TriangleEngine`` / ``QueryEngine.from_graph`` orient it."""
        a, b = orient_edges(np.asarray(src), np.asarray(dst), orientation)
        nv = int(max(a.max(initial=-1), b.max(initial=-1))) + 1
        ip, ix = csr_from_edges(a, b, n_nodes=nv) if nv else \
            (np.zeros(1, np.int64), np.zeros(0, np.int32))
        return cls({relation: InMemoryEdgeSource(ip, ix,
                                                 orientation=orientation)},
                   **kw)

    # -- plan / order caches ---------------------------------------------------

    @staticmethod
    def _resolve_query(query) -> Query:
        if isinstance(query, str):
            try:
                return PATTERNS[query]()
            except KeyError:
                raise ValueError(
                    f"unknown pattern {query!r}; known: {list(PATTERNS)}")
        return query

    def _shape_sig(self, query: Query) -> tuple:
        return (tuple((a.rel, tuple(a.vars)) for a in query.atoms),
                tuple(query.head))

    def _order_for(self, query: Query) -> Tuple[str, ...]:
        """Variable order memoized per pattern shape. Consistency with
        every atom is REQUIRED (store-backed relations can't build
        reordered indexes), so an order-less shape without one is rejected
        at submit, not mid-run."""
        sig = self._shape_sig(query)
        with self._lock:
            order = self._orders.get(sig)
        if order is None:
            order = validate(query, None, require_consistent=True)
            with self._lock:
                self._orders[sig] = order
        return order

    def _plan_key(self, query: Query, order: Sequence[str],
                  m_words: int) -> str:
        """Plan-cache key, keyed the way ``core.engine`` keys its
        crossover cache: every planning input that changes the boxes —
        pattern shape, variable order, budget, skew lane policy — in one
        string (the degree indexes are fixed for a server's lifetime)."""
        sig = self._shape_sig(query)
        return (f"{sig}:{tuple(order)}:m{m_words}:{self.skew}"
                f":h{self.heavy_threshold}")

    # -- submission ------------------------------------------------------------

    def submit(self, query, mode: str = "count", *,
               want_words: Optional[int] = None,
               workers: Optional[int] = None,
               capacity: Optional[int] = None,
               stream: bool = False,
               block: bool = True,
               timeout: Optional[float] = None) -> QueryHandle:
        """Admit and launch one query; returns its handle immediately.

        Admission happens HERE, synchronously: ``AdmissionRejected`` /
        ``AdmissionTimeout`` raise from ``submit`` (graceful rejection —
        nothing was started), with ``block``/``timeout`` selecting between
        immediate rejection and bounded queueing. ``mode`` is 'count' or
        'list'; ``stream=True`` (list only) pages results through
        ``handle.pages()``."""
        if self._closed:
            raise QueryError("server is closed")
        if mode not in ("count", "list"):
            raise ValueError(f"mode {mode!r} not in ('count', 'list')")
        if stream and mode != "list":
            raise ValueError("stream=True needs mode='list'")
        query = self._resolve_query(query)
        missing = [a.rel for a in query.atoms if a.rel not in self._sources]
        if missing:
            raise ValueError(f"unknown relation(s) {sorted(set(missing))}; "
                             f"serving {sorted(self._sources)}")
        order = self._order_for(query)     # rejects unservable shapes early

        qid = f"q{next(self._qid)}"
        h = QueryHandle(qid, query, mode)
        h.submitted_at = time.perf_counter()
        h.workers = self.workers_per_query if workers is None \
            else max(1, int(workers))
        h.capacity = capacity
        h.order = order
        # the admission gate: may queue (bounded) or raise AdmissionError
        if self.tracer is not None:
            with self.tracer.span("serve.admission", qid=qid, mode=mode):
                reservation = self.admission.acquire(
                    want_words, timeout=timeout, block=block, tag=qid)
        else:
            reservation = self.admission.acquire(
                want_words, timeout=timeout, block=block, tag=qid)
        h.admitted_words = reservation.words
        h.cache_floor = self.floor_words
        if stream:
            h._stream = _PageStream(
                lambda rows: rows,      # rebound once the engine exists
                h._cancel, self.page_rows, self.page_queue_depth)
        with self._lock:
            self._handles[qid] = h
        t = threading.Thread(target=self._runner, args=(h, reservation),
                             name=f"serve-{qid}", daemon=True)
        h._thread = t
        h.status = "running"
        t.start()
        return h

    # -- the per-query runner --------------------------------------------------

    def _runner(self, h: QueryHandle, reservation) -> None:
        try:
            if self.tracer is not None:
                with self.tracer.span("serve.query", qid=h.qid,
                                      mode=h.mode,
                                      words=reservation.words):
                    self._runner_impl(h, reservation)
            else:
                self._runner_impl(h, reservation)
        finally:
            # end-to-end latency (admission wait included: measured from
            # submit) feeding the serve.latency_s p50/p90/p99 histograms
            if self.metrics is not None and h.submitted_at is not None:
                self.metrics.observe(
                    "serve.latency_s",
                    time.perf_counter() - h.submitted_at,
                    mode=h.mode, status=h.status)

    def _runner_impl(self, h: QueryHandle, reservation) -> None:
        views: Dict[str, TenantView] = {}
        tag_opened = False
        try:
            qid, m = h.qid, reservation.words
            rel_names: List[str] = []
            for a in h.query.atoms:
                if a.rel not in rel_names:
                    rel_names.append(a.rel)
            # register this query as a tenant of every relation cache it
            # reads; if the floors are momentarily oversubscribed (admission
            # races a finishing query's unregister) fall back to floor 0 —
            # correctness is unaffected, only the residency guarantee.
            sources: Dict[str, object] = {}
            for name in rel_names:
                cache = self.caches.get(name)
                if cache is None:
                    sources[name] = self._sources[name]
                    continue
                try:
                    views[name] = cache.register(qid, h.cache_floor)
                except ValueError:
                    views[name] = cache.register(qid, 0)
                sources[name] = views[name]
            self.device.open_tag(qid, max(2, m // self.device.B))
            tag_opened = True

            plan_key = self._plan_key(h.query, h.order, m)
            with self._lock:
                plan0 = self._plans.get(plan_key)
            eng = QueryEngine(h.query, relations=sources, order=h.order,
                              mem_words=m, cache_words=0,
                              device=self.device, backend=self.backend,
                              workers=h.workers, skew=self.skew,
                              heavy_threshold=self.heavy_threshold,
                              plan=plan0, cancel=h._cancel,
                              use_pallas_kernels=self._use_pallas,
                              tracer=self.tracer, metrics=self.metrics)
            plan = eng.plan()
            with self._lock:
                if plan0 is not None:
                    self.plan_hits += 1
                else:
                    self.plan_misses += 1
                    self._plans[plan_key] = plan
            if h._stream is not None:
                h._stream._head = eng.head_columns
            eng._reset_stats(plan)
            self._drive(h, eng, plan)
            self._finalize(h, eng, qid, views)
            h.status = "done"
        except BoxQueueCancelled as e:
            h.status = "cancelled"
            h._error = QueryCancelled(str(e))
        except AdmissionError as e:       # cache floor races, defensive
            h.status = "error"
            h._error = QueryFailed(f"query {h.qid}: {e}", e)
        except QueryError as e:
            h.status = "error"
            h._error = e
        except BaseException as e:
            h.status = "error"
            h._error = QueryFailed(f"query {h.qid} failed: {e}", e)
        finally:
            for name, view in views.items():
                self.caches[name].unregister(h.qid)
            if tag_opened:
                self.device.close_tag(h.qid)
            reservation.release()
            if h._stream is not None:
                h._stream.finish(h._error)
            h._done.set()

    def _drive(self, h: QueryHandle, eng: QueryEngine, plan) -> None:
        """Rounds of the shared box queue with per-box fault capture:
        completed boxes keep their results (dedup by box id in the
        scheduler), failed ones re-queue for the next round."""
        qid = h.qid
        cap = h.capacity if h.capacity is not None \
            else eng.default_list_capacity()
        est, fetch, build, work = eng.box_stages(h.mode, cap)
        sched = BoxScheduler(plan.boxes, n_workers=h.workers)
        hook = self.fault_hook

        def fetch_w(item):
            i, box = item
            try:
                if hook is not None:
                    hook("fetch", qid, i)
                with self.device.attributed(qid):
                    payload, words = fetch(box)
                return (i, payload), words
            except BaseException as e:          # noqa: BLE001 — captured
                return (i, _BoxError(e)), 0

        def build_w(payload):
            i, p = payload
            if p is None or isinstance(p, _BoxError):
                return (i, p)
            try:
                if hook is not None:
                    hook("build", qid, i)
                return (i, build(p))
            except BaseException as e:          # noqa: BLE001 — captured
                return (i, _BoxError(e))

        def work_w(built):
            i, b = built
            if b is None or isinstance(b, _BoxError):
                out = (i, b)
            else:
                try:
                    if hook is not None:
                        hook("work", qid, i)
                    with self.device.attributed(qid):
                        out = (i, work(b))
                except BaseException as e:      # noqa: BLE001 — captured
                    out = (i, _BoxError(e))
            if h._stream is not None and not isinstance(out[1], _BoxError):
                h._stream.offer(out[0], out[1])
                tr = self.tracer
                if tr is not None:
                    tr.event("serve.page.offer", qid=qid, box=out[0])
            return out

        last_err: Optional[BaseException] = None
        rounds = 0
        while True:
            pending = sched.pending()
            if not pending:
                break
            if h._cancel.is_set():
                raise BoxQueueCancelled(f"query {qid} cancelled")
            items = [(i, sched.tasks[i].payload) for i in pending]
            results, tele = run_box_queue(
                items,
                order=eng.queue_order([b for _, b in items]),
                est_words=lambda it: est(it[1]),
                fetch=fetch_w, build=build_w, work=work_w,
                workers=h.workers,
                inflight_items=eng.inflight_boxes,
                inflight_words=eng.inflight_boxes * eng.mem_words
                if eng.mem_words is not None else None,
                cancel=h._cancel,
                tracer=self.tracer)
            merge_queue_telemetry(eng.stats, tele, eng._stats_lock,
                                  inflight_boxes=eng.inflight_boxes,
                                  metrics=self.metrics)
            failed: List[int] = []
            for out in results:
                if out is None:
                    continue
                i, r = out
                if isinstance(r, _BoxError):
                    failed.append(i)
                    last_err = r.exc
                else:
                    sched.complete(0, i, r)
            if failed:
                rounds += 1
                if rounds > self.box_retries:
                    raise QueryFailed(
                        f"query {qid}: {len(failed)} box(es) still failing "
                        f"after {self.box_retries} retry round(s): "
                        f"{last_err}", last_err)
                sched.requeue(failed)
        h.retry_rounds = rounds
        h._sched = sched

    def _finalize(self, h: QueryHandle, eng: QueryEngine, qid: str,
                  views: Dict[str, TenantView]) -> None:
        results = h._sched.results()
        if h.mode == "count":
            h._result = sum(int(r) for r in results if r is not None)
            eng.stats.n_results = h._result
        else:
            parts = [r for r in results if r is not None]
            rows = np.concatenate(parts) if parts \
                else np.zeros((0, eng.n), dtype=np.int64)
            eng.stats.n_results = len(rows)
            h._result = eng.head_columns(rows)
        # per-query I/O from the device partition (the shared device's
        # global mark/collect would mix concurrent queries)
        t = self.device.tag_stats(qid)
        eng.stats.block_reads = t.block_reads
        eng.stats.block_writes = t.block_writes
        eng.stats.word_reads = t.word_reads
        for view in views.values():
            st = view.stats
            eng.stats.cache_hits += st.hits
            eng.stats.cache_misses += st.misses
            eng.stats.cache_hit_words += st.hit_words
        h.stats = eng.stats
        if self.metrics is not None:
            # QueryStats published as query.*{qid=..} gauges: the run-level
            # dataclass becomes a view the registry also holds
            self.metrics.publish_stats(eng.stats, "query", qid=qid,
                                       mode=h.mode)

    # -- solo oracle -----------------------------------------------------------

    def solo_run(self, query, mode: str = "count", *,
                 words: int, capacity: Optional[int] = None):
        """The per-query *solo envelope*: the same query on a fresh
        isolated stack — its own device with ``words/B`` frames, fresh
        sources, a private slice cache at this server's per-tenant floor —
        at budget ``words``. ``(result, QueryStats)``; the serving suite
        pins result exactness against it and the load benchmark bounds
        aggregate ``block_reads`` by the sum of these envelopes."""
        query = self._resolve_query(query)
        dev = BlockDevice(block_words=self.device.B,
                          cache_blocks=max(2, words // self.device.B))
        rels: Dict[str, object] = {}
        for name, spec in self._specs.items():
            if spec[0] == "store":
                rels[name] = EdgeStore(spec[1], device=dev)
            else:
                rels[name] = InMemoryEdgeSource(spec[1], spec[2],
                                                device=dev,
                                                orientation=spec[3])
        eng = QueryEngine(query, relations=rels,
                          order=self._order_for(query),
                          mem_words=words,
                          cache_words=self.floor_words,
                          device=dev, backend=self.backend,
                          workers=1, skew=self.skew,
                          heavy_threshold=self.heavy_threshold,
                          use_pallas_kernels=self._use_pallas)
        out = eng.count() if mode == "count" else eng.list(capacity)
        return out, eng.stats

    # -- fabric-backed sessions ------------------------------------------------

    def fabric_run(self, query, mode: str = "count", *,
                   n_shards: Optional[int] = None,
                   want_words: Optional[int] = None,
                   workers: Optional[int] = None,
                   capacity: Optional[int] = None,
                   block: bool = True,
                   timeout: Optional[float] = None
                   ) -> Tuple[object, FabricStats]:
        """One query through the distributed box fabric
        (``parallel.fabric``) over this server's warm relations.

        Admission reserves ``want_words`` exactly like ``submit`` — the
        reservation is the PER-SHARD working budget (each shard models a
        remote host's local memory) and bounds the planning/shipping
        footprint on this server; shipping reads are charged to the
        server's shared device under a per-run attribution tag, while
        every shard executes against its own fresh device (the per-shard
        ledgers in the returned ``FabricStats`` keep the solo-oracle
        contract). Blocking call; returns ``(result, FabricStats)``."""
        if self._closed:
            raise QueryError("server is closed")
        if mode not in ("count", "list"):
            raise ValueError(f"mode {mode!r} not in ('count', 'list')")
        query = self._resolve_query(query)
        missing = [a.rel for a in query.atoms if a.rel not in self._sources]
        if missing:
            raise ValueError(f"unknown relation(s) {sorted(set(missing))}; "
                             f"serving {sorted(self._sources)}")
        order = self._order_for(query)
        tag = f"fab{next(self._qid)}"
        reservation = self.admission.acquire(
            want_words, timeout=timeout, block=block, tag=tag)
        self.device.open_tag(tag, max(2, reservation.words // self.device.B))
        try:
            fab = Fabric(query, relations=dict(self._sources), order=order,
                         n_shards=n_shards, mem_words=reservation.words,
                         cache_words=self.floor_words,
                         io_block_words=self.device.B,
                         backend=self.backend,
                         workers=self.workers_per_query
                         if workers is None else max(1, int(workers)),
                         skew=self.skew,
                         heavy_threshold=self.heavy_threshold,
                         device=self.device,
                         use_pallas_kernels=self._use_pallas,
                         tracer=self.tracer, metrics=self.metrics)
            with self.device.attributed(tag):
                out = fab.count() if mode == "count" else fab.list(capacity)
            return out, fab.stats
        finally:
            self.device.close_tag(tag)
            reservation.release()

    # -- lifecycle -------------------------------------------------------------

    def handles(self) -> List[QueryHandle]:
        with self._lock:
            return list(self._handles.values())

    def close(self, timeout: float = 30.0) -> None:
        """Cancel every live query and join all runner threads."""
        self._closed = True
        for h in self.handles():
            if not h.done():
                h.cancel()
        for h in self.handles():
            if h._thread is not None:
                h._thread.join(timeout)

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class Session:
    """Blocking convenience facade over one ``Server``: ``count`` /
    ``list`` submit-and-wait; per-session defaults for the submit knobs."""

    def __init__(self, server: Server, **defaults):
        self.server = server
        self.defaults = defaults
        self._live: List[QueryHandle] = []

    def submit(self, query, mode: str = "count", **kw) -> QueryHandle:
        merged = dict(self.defaults)
        merged.update(kw)
        h = self.server.submit(query, mode, **merged)
        self._live.append(h)
        return h

    def count(self, query, **kw) -> int:
        return self.submit(query, "count", **kw).result()

    def list(self, query, **kw) -> np.ndarray:
        return self.submit(query, "list", **kw).result()

    def fabric_count(self, query, **kw) -> int:
        """Distributed count through the server's box fabric
        (``Server.fabric_run``); session defaults apply."""
        merged = dict(self.defaults)
        merged.update(kw)
        return self.server.fabric_run(query, "count", **merged)[0]

    def fabric_list(self, query, **kw) -> np.ndarray:
        merged = dict(self.defaults)
        merged.update(kw)
        return self.server.fabric_run(query, "list", **merged)[0]

    def close(self) -> None:
        for h in self._live:
            if not h.done():
                h.cancel()
        for h in self._live:
            h.wait(30.0)
        self._live.clear()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
