"""Persistent concurrent query serving (PR 8).

``Server`` keeps relations warm (mmap ``EdgeStore`` / in-memory CSR on one
shared ``BlockDevice``) and serves concurrent pattern queries with the
paper's per-query I/O envelopes intact: admission control partitions
``mem_words`` into per-query reservations (boxes planned against the
partition, Thm. 10/13 per query), a floor-protected ``SharedSliceCache``
spans queries per relation, box plans are memoized per pattern shape, and
failed/cancelled boxes re-queue idempotently through the straggler
scheduler. See ``serve.server`` / ``serve.admission`` / ``serve.cache``.

    with Server.from_graph(src, dst, mem_words=1 << 20) as srv:
        h = srv.submit("triangle", "count")
        n = h.result()
        for page in srv.submit("four_clique", "list",
                               stream=True).pages():
            ...
"""

from .admission import (AdmissionController, AdmissionError,
                        AdmissionRejected, AdmissionTimeout, Reservation)
from .cache import SharedSliceCache, TenantStats, TenantView
from .server import (QueryCancelled, QueryError, QueryFailed, QueryHandle,
                     Server, Session)

__all__ = [
    "AdmissionController", "AdmissionError", "AdmissionRejected",
    "AdmissionTimeout", "Reservation",
    "SharedSliceCache", "TenantStats", "TenantView",
    "QueryCancelled", "QueryError", "QueryFailed", "QueryHandle",
    "Server", "Session",
]
