"""Multi-tenant shared ``SliceCache`` for the serving layer.

One warm graph serves many concurrent queries, and adjacent queries walk
overlapping row ranges — the whole point of a resident server is that
query B hits the slabs query A just pulled in. But a naively shared LRU
lets one scan-heavy query evict everything, silently destroying the
budget-partition contract admission control just established.

``SharedSliceCache`` extends the single-query :class:`~repro.core.executor.
SliceCache` with *tenants*:

* every admitted query registers with a **floor** — a slice of the cache
  budget reserved for it (``Σ floors ≤ budget_words``, checked);
* every cached block has an **owner**: the tenant whose miss fetched it
  (blocks of departed tenants become ownerless);
* eviction is **floor-protected LRU**: walking blocks in global LRU
  order, a block is evictable only if it is ownerless or its owner holds
  strictly more cached words than its floor *after* the eviction. A
  tenant therefore always keeps at least ``floor`` words of its own
  hottest blocks resident no matter what its neighbours do — its miss
  count is bounded by a solo run with a ``floor``-sized cache
  (inclusion), while everything above the floors is genuinely shared
  (cross-tenant hits are free wins, and the stress suite checks they
  only ever *reduce* cache-layer misses).

Accounting is two-level: per-tenant ``{hits, misses, hit_words,
miss_words, passthrough_words, words}`` plus the inherited global
counters — the property tests assert the tenant ledgers sum exactly to
the global one. ``snapshot()`` byte-captures the cache contents so the
fault-injection suite can prove a failed query never poisons what its
neighbours see.

Tenants access the cache through :class:`TenantView`, which looks like an
EdgeSource (it IS what the server hands ``QueryEngine`` as a relation):
``read_rows`` routes through the shared cache with this tenant's
attribution; every other attribute proxies to the underlying source.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.executor import SliceCache


class TenantStats:
    """Mutable per-tenant cache ledger (kept after ``unregister``)."""

    __slots__ = ("floor", "words", "hits", "misses",
                 "hit_words", "miss_words", "passthrough_words")

    def __init__(self, floor: int):
        self.floor = int(floor)
        self.words = 0
        self.hits = 0
        self.misses = 0
        self.hit_words = 0
        self.miss_words = 0
        self.passthrough_words = 0

    def as_dict(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in self.__slots__}


class SharedSliceCache(SliceCache):
    """One cache, many queries; floor-protected eviction (module doc)."""

    def __init__(self, source, budget_words: int,
                 block_rows: Optional[int] = None, tracer=None):
        super().__init__(source, budget_words, block_rows, tracer=tracer)
        self._owner: Dict[int, object] = {}       # block id -> tenant | None
        self._tenants: Dict[object, TenantStats] = {}
        self._gone: Dict[object, TenantStats] = {}  # stats after unregister
        self._cur: Optional[object] = None          # tenant of current read
        self.cross_hits = 0      # hits on a block some other tenant fetched

    # -- tenant lifecycle ----------------------------------------------------

    def register(self, tenant, floor_words: int = 0) -> "TenantView":
        with self._lock:
            if tenant in self._tenants:
                raise ValueError(f"tenant {tenant!r} already registered")
            floors = sum(t.floor for t in self._tenants.values())
            floor_words = int(floor_words)
            if floors + floor_words > self.budget_words:
                raise ValueError(
                    f"floor {floor_words} would oversubscribe the cache: "
                    f"{floors}/{self.budget_words} words already reserved")
            self._tenants[tenant] = TenantStats(floor_words)
            return TenantView(self, tenant)

    def unregister(self, tenant) -> TenantStats:
        """Drop a tenant: its blocks stay cached (warm for neighbours) but
        become ownerless — freely evictable. Returns its final ledger."""
        with self._lock:
            st = self._tenants.pop(tenant)
            for bid, owner in list(self._owner.items()):
                if owner == tenant:
                    self._owner[bid] = None
            self._gone[tenant] = st
            return st

    def tenant_stats(self, tenant) -> TenantStats:
        with self._lock:
            return self._tenants.get(tenant) or self._gone[tenant]

    def all_tenant_stats(self) -> Dict[object, TenantStats]:
        """Every tenant's ledger, live AND departed (``unregister`` keeps
        the final stats). The observability registry mirrors this into
        ``cache.*{tenant=...}`` series; because every ``read_rows_for``
        is attributed, the per-tenant counters sum exactly to the
        inherited global ones."""
        with self._lock:
            out: Dict[object, TenantStats] = dict(self._gone)
            out.update(self._tenants)
            return out

    # -- attributed reads ----------------------------------------------------

    def read_rows_for(self, tenant, lo: int,
                      hi: int) -> Tuple[np.ndarray, np.ndarray]:
        with self._lock:
            if tenant not in self._tenants:
                raise KeyError(f"tenant {tenant!r} not registered")
            prev, self._cur = self._cur, tenant
            try:
                return self._read_rows_locked(lo, hi)
            finally:
                self._cur = prev

    # -- SliceCache hooks: per-tenant attribution ----------------------------

    def _hit(self, bid: int, ent) -> None:
        super()._hit(bid, ent)
        st = self._tenants.get(self._cur)
        if st is not None:
            st.hits += 1
            st.hit_words += len(ent[1])
        if self._owner.get(bid) != self._cur:
            self.cross_hits += 1

    def _miss(self, n_blocks: int, n_words: int) -> None:
        super()._miss(n_blocks, n_words)
        st = self._tenants.get(self._cur)
        if st is not None:
            st.misses += n_blocks
            st.miss_words += n_words

    def _read_through(self, lo: int, hi: int):
        ip, vals = super()._read_through(lo, hi)
        st = self._tenants.get(self._cur)
        if st is not None:
            st.passthrough_words += len(vals)
        return ip, vals

    # -- floor-protected eviction --------------------------------------------

    def _evictable_locked(self, bid: int) -> bool:
        owner = self._owner.get(bid)
        st = self._tenants.get(owner) if owner is not None else None
        if st is None:
            return True
        return st.words - self._entry_words(self._blocks[bid]) >= st.floor

    def _insert(self, bid: int, ent) -> None:
        # (re)charge the inserting tenant for this block
        old = self._blocks.pop(bid, None)
        if old is not None:
            self._words -= self._entry_words(old)
            self._uncharge(bid, old)
        self._blocks[bid] = ent
        self._words += self._entry_words(ent)
        self._owner[bid] = self._cur
        st = self._tenants.get(self._cur)
        if st is not None:
            st.words += self._entry_words(ent)
        while self._words > self.budget_words and len(self._blocks) > 1:
            victim = None
            for vbid in self._blocks:           # global LRU order
                if vbid != bid and self._evictable_locked(vbid):
                    victim = vbid
                    break
            if victim is None:
                # every other resident block sits inside some tenant's
                # floor: soft-exceed the budget rather than break the
                # reservation contract (the floors sum ≤ budget, so the
                # overshoot is bounded by one block per tenant)
                break
            vent = self._blocks.pop(victim)
            self._words -= self._entry_words(vent)
            self._uncharge(victim, vent)
            tr = self.tracer
            if tr is not None:
                tr.event("cache.evict", block=victim,
                         words=self._entry_words(vent))

    def _uncharge(self, bid: int, ent) -> None:
        owner = self._owner.pop(bid, None)
        st = self._tenants.get(owner) if owner is not None else None
        if st is not None:
            st.words -= self._entry_words(ent)

    def clear(self) -> None:
        with self._lock:
            super().clear()
            self._owner.clear()
            for st in self._tenants.values():
                st.words = 0

    # -- fault-injection forensics -------------------------------------------

    def snapshot(self) -> Dict[int, Tuple[bytes, bytes]]:
        """Byte-exact capture of the cache contents (LRU order implicit in
        key iteration): the poisoning test diffs this across a failed
        neighbour query."""
        with self._lock:
            return {bid: (ent[0].tobytes(), ent[1].tobytes())
                    for bid, ent in self._blocks.items()}


class TenantView:
    """EdgeSource facade binding one tenant to the shared cache.

    ``read_rows`` goes through the shared cache with this tenant's
    attribution; all other attributes (``n_nodes``, ``degrees``,
    ``indptr``, ``device``, ...) proxy to the wrapped source, so a
    ``QueryEngine`` can use a view anywhere it accepts an EdgeSource.
    """

    def __init__(self, shared: SharedSliceCache, tenant):
        self._shared = shared
        self._tenant = tenant

    def read_rows(self, lo: int, hi: int) -> Tuple[np.ndarray, np.ndarray]:
        return self._shared.read_rows_for(self._tenant, lo, hi)

    @property
    def stats(self) -> TenantStats:
        return self._shared.tenant_stats(self._tenant)

    def __getattr__(self, name):
        return getattr(self._shared.source, name)
