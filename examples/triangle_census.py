"""End-to-end driver (the paper's kind: out-of-core graph query serving).

Pipeline, exactly as a production deployment would run it:

  1. ingest a large RMAT graph, orient + build the TrieArray (O(sort));
  2. plan boxes against a memory budget (the paper's probe/provision);
  3. execute box-parallel with the fault-tolerant scheduler (a simulated
     worker dies mid-run; a straggler gets its box stolen) — results are
     exact because boxes are idempotent;
  4. per-node triangle counts become clustering-coefficient features;
  5. a GCN consumes the features for a few training steps (shared CSR
     substrate: the same arrays feed message passing).

    PYTHONPATH=src python examples/triangle_census.py [--edges 200000]
"""

import argparse
import time

import numpy as np

from repro.core import TriangleEngine
from repro.core.lftj_jax import _count_chunked
from repro.data.graphs import rmat_graph
from repro.runtime.straggler import BoxScheduler, fail_worker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1 << 13)
    ap.add_argument("--edges", type=int, default=200_000)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--mem-frac", type=float, default=0.15)
    args = ap.parse_args()

    t0 = time.time()
    src, dst = rmat_graph(args.nodes, args.edges, seed=0)
    eng = TriangleEngine(src, dst, shard=False)  # scheduler plays the mesh
    a, b = eng.a, eng.b
    print(f"[ingest] {len(a)} edges -> CSR over {eng.nv} nodes "
          f"({time.time()-t0:.1f}s)")

    eng.mem_words = int((len(a) * 2 + eng.nv) * args.mem_frac)
    boxes = eng.plan()
    print(f"[plan]   {len(boxes)} boxes @ {args.mem_frac:.0%} memory budget")

    import jax.numpy as jnp

    def solve(box):
        eu, ev, _, _ = eng._box_edges(box)
        if len(eu) == 0:
            return 0
        return int(_count_chunked(eng.npad, jnp.asarray(eu, jnp.int32),
                                  jnp.asarray(ev, jnp.int32), chunk=1024))

    sched = BoxScheduler(boxes, n_workers=args.workers, steal_after_s=0.0)
    # chaos: worker 0 grabs work and dies
    sched.next_for(0, now=0.0)
    n_requeued = fail_worker(sched, 0)
    t1 = time.time()
    while not sched.all_done():
        for w in range(1, args.workers):
            t = sched.next_for(w, now=1e9)
            if t is not None:
                sched.complete(w, t.box_id, solve(t.payload))
    total = sum(sched.results())
    print(f"[boxes]  {total} triangles in {time.time()-t1:.1f}s on "
          f"{args.workers - 1} surviving workers "
          f"(1 worker killed, {n_requeued} boxes re-queued, "
          f"{sched.duplicates} steals)")
    check = eng.count()  # same engine, in-process (sharded if multi-device)
    assert total == check, (total, check)
    print(f"[verify] matches TriangleEngine.count(): {check} "
          f"({eng.stats.n_dense_boxes}/{eng.stats.n_boxes} dense boxes, "
          f"{eng.stats.n_shards} shard(s))")

    # degree + global clustering features -> GCN (shared CSR substrate)
    deg = np.bincount(np.concatenate([a, b]), minlength=eng.nv)
    cc = np.minimum(total * 3 / max(1, len(a)), 1.0) * np.ones(eng.nv)
    feats = np.stack([deg / max(1, deg.max()), cc,
                      np.log1p(deg)], 1).astype(np.float32)

    import dataclasses
    import jax
    from repro.configs import get_arch
    from repro.models import gnn as G, layers as L
    from repro.optim import adamw
    L.set_dtypes(jnp.float32, jnp.float32)
    cfg = dataclasses.replace(get_arch("gcn-cora").smoke_config,
                              d_in=3, d_out=2)
    params = G.init_params(cfg, jax.random.PRNGKey(0))
    n = feats.shape[0]
    batch = {"node_feat": jnp.asarray(feats),
             "edge_src": jnp.asarray(a, jnp.int32),
             "edge_dst": jnp.asarray(b, jnp.int32),
             "edge_mask": jnp.ones(len(a)), "node_mask": jnp.ones(n),
             "labels": jnp.asarray(deg > np.median(deg), jnp.int32),
             "label_mask": jnp.ones(n)}
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=30)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda p: G.loss_fn(cfg, p, batch)[0])(p)
        p, o, _ = adamw.apply(ocfg, p, g, o)
        return p, o, loss

    losses = []
    for i in range(30):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    print(f"[gnn]    GCN on census features: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f} over 30 steps")
    print(f"[done]   total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
