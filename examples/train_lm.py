"""End-to-end LM training example with checkpoint/restart.

Default is a ~10M-parameter Qwen2-style model sized for this CPU
container; ``--full-100m`` selects the ~100M configuration that the same
driver trains on accelerators (documented run: a few hundred steps).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --resume
"""

import argparse

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import LayerSpec, TransformerConfig


def config(full_100m: bool) -> TransformerConfig:
    if full_100m:
        return TransformerConfig(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab=32768, qkv_bias=True,
            pattern=(LayerSpec(),))
    return TransformerConfig(
        name="lm-10m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
        d_head=32, d_ff=768, vocab=8192, qkv_bias=True,
        pattern=(LayerSpec(),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    L.set_dtypes(jnp.float32, jnp.float32)
    cfg = config(args.full_100m)

    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import Prefetcher
    from repro.data.tokens import TokenStream
    from repro.models import transformer as M
    from repro.optim import adamw

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_params = cfg.params_count()
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    opt_cfg = adamw.AdamWConfig(lr=6e-4, warmup_steps=20,
                                total_steps=args.steps)
    opt = adamw.init(params)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        (params, opt), start = mgr.restore((params, opt))
        print(f"resumed at step {start}")

    stream = TokenStream(cfg.vocab, seed=0)
    batches = Prefetcher(
        (stream.batch(args.batch, args.seq)
         for _ in range(args.steps - start)), depth=2)

    @jax.jit
    def step(p, o, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(p)
        p, o, m = adamw.apply(opt_cfg, p, g, o)
        return p, o, loss

    import time
    t0 = time.time()
    for i, b in enumerate(batches, start=start):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, loss = step(params, opt, b)
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i - start + 1) / (time.time() - t0)
            print(f"step {i:4d} loss {float(loss):.4f} ({tok_s:,.0f} tok/s)")
        if (i + 1) % 50 == 0:
            mgr.save(i + 1, (params, opt))
    mgr.save(args.steps, (params, opt))
    mgr.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
