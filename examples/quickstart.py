"""Quickstart: the public triangle-listing API in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (BlockDevice, TrieArray, boxed_triangle_count,
                        count_triangles, list_triangles, orient_edges,
                        Atom, Query, run_query)
from repro.data.graphs import rmat_graph


def main():
    # 1. make a graph (the paper's RMAT synthetic dataset, scaled down)
    src, dst = rmat_graph(n_nodes=1 << 12, n_edges=50_000, seed=0)
    print(f"graph: {len(src)} edges")

    # 2. count triangles — every altitude agrees
    for method in ("vectorized", "faithful", "dense", "mgt"):
        print(f"  {method:11s}: {count_triangles(src, dst, method=method, mem_words=1 << 14)}")

    # 3. list them
    tri = list_triangles(src, dst)
    print(f"  listed {len(tri)} triangles; first: {tri[0].tolist() if len(tri) else '—'}")

    # 4. out-of-core: budget memory at 10% of the input, watch the boxes
    a, b = orient_edges(src, dst)
    ta = TrieArray.from_edges(a, b)
    dev = BlockDevice(block_words=64, cache_blocks=ta.words() // 10 // 64)
    dev.register_triearray(ta)
    cnt, stats = boxed_triangle_count(ta, ta.words() // 10, block_words=64,
                                      device=dev)
    print(f"boxed @10% memory: {cnt} triangles, {stats.n_boxes} boxes, "
          f"{dev.stats.block_reads} block I/Os "
          f"({stats.provisioned_words / ta.words():.1f}x input provisioned)")

    # 5. LFTJ is general-purpose: any full-conjunctive query (paths, here)
    rels = {"E": ta}
    q = Query(("x", "y", "z"),
              [Atom("E", ("x", "y")), Atom("E", ("y", "z"))])
    n_paths = run_query(q, ["x", "y", "z"], rels)
    print(f"2-paths via the same engine: {n_paths}")


if __name__ == "__main__":
    main()
