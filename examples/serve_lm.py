"""Batched LM serving example: chunked prefill + continuous decode.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 24
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    L.set_dtypes(jnp.float32, jnp.float32)
    from repro.configs import get_arch
    from repro.launch.serve import generate
    from repro.models import transformer as M

    cfg = get_arch(args.arch).smoke_config
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    t0 = time.time()
    toks = generate(cfg, params, prompts, args.gen)
    dt = time.time() - t0
    print(f"served {args.batch} requests x {args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    for i, row in enumerate(toks[:2]):
        print(f"  req{i}: {row[:12].tolist()}...")


if __name__ == "__main__":
    main()
