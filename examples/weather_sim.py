"""GraphCast on its native icosahedral multimesh (beyond-assignment extra).

Builds the refinement-r multimesh, synthesizes grid states, runs the
encoder-processor-decoder a few training steps of one-step-ahead
forecasting (targets = diffused current state).

    PYTHONPATH=src python examples/weather_sim.py --refinement 3
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--refinement", type=int, default=3)
    ap.add_argument("--vars", type=int, default=16)
    ap.add_argument("--steps", type=int, default=15)
    args = ap.parse_args()

    L.set_dtypes(jnp.float32, jnp.float32)
    from repro.configs import get_arch
    from repro.data.graphs import icosahedral_mesh
    from repro.models import gnn as G
    from repro.optim import adamw

    verts, src, dst = icosahedral_mesh(args.refinement)
    n = len(verts)
    print(f"multimesh r={args.refinement}: {n} nodes, {len(src)} edges")

    rng = np.random.default_rng(0)
    # smooth synthetic atmospheric state: low-order spherical harmonics-ish
    state = np.tanh(verts @ rng.standard_normal((3, args.vars))).astype(np.float32)
    # target: one diffusion step along mesh edges (a simple but nontrivial
    # local dynamical operator the EPD stack must learn)
    agg = np.zeros_like(state)
    np.add.at(agg, dst, state[src])
    np.add.at(agg, src, state[dst])
    deg = np.bincount(np.concatenate([src, dst]), minlength=n)[:, None]
    target = 0.7 * state + 0.3 * agg / np.maximum(deg, 1)

    cfg = dataclasses.replace(get_arch("graphcast").smoke_config,
                              d_in=args.vars, d_out=args.vars, n_layers=4)
    params = G.init_params(cfg, jax.random.PRNGKey(0))
    edge_feat = np.concatenate(
        [verts[src] - verts[dst],
         np.linalg.norm(verts[src] - verts[dst], axis=1, keepdims=True)],
        axis=1).astype(np.float32)
    batch = {"node_feat": jnp.asarray(state),
             "edge_src": jnp.asarray(src, jnp.int32),
             "edge_dst": jnp.asarray(dst, jnp.int32),
             "edge_feat": jnp.asarray(edge_feat),
             "edge_mask": jnp.ones(len(src)), "node_mask": jnp.ones(n),
             "targets": jnp.asarray(target)}

    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=args.steps)
    opt = adamw.init(params)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda p: G.loss_fn(cfg, p, batch)[0])(p)
        p, o, _ = adamw.apply(opt_cfg, p, g, o)
        return p, o, loss

    for i in range(args.steps):
        params, opt, loss = step(params, opt)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} forecast MSE {float(loss):.5f}")


if __name__ == "__main__":
    main()
